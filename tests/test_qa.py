"""repro.qa public API: execution-strategy equivalence grid, fluent builder
semantics, polymorphic ingest, and declarative custom metrics."""
import dataclasses
import os
import tempfile
import time

import numpy as np
import pytest

from repro import qa
from repro.core import ALL_METRICS, PAPER_METRICS, QualityEvaluator, plan
from repro.core import metrics as M
from repro.rdf import bsbm_ntriples, synth_encoded

N = 10_000


@pytest.fixture(scope="module")
def tensor():
    return synth_encoded(N, seed=3)


@pytest.fixture(scope="module")
def reference(tensor):
    return qa.assess(tensor, metrics=ALL_METRICS)  # fused, jnp, single-shot


# --- acceptance: every execution strategy yields identical values ------------

BACKENDS = ("jnp", "pallas", "fused_scan")


def _expected_passes_per_chunk(evaluator) -> int:
    """Actual data passes, derived from the plan structure: fused_scan
    folds every sketch into the counter scan; jnp/pallas pay one extra
    scan per sketch."""
    if evaluator.backend == "fused_scan":
        return len(evaluator.plans)
    return sum(1 + len(p.sketch_specs) for p in evaluator.plans)


@pytest.mark.parametrize("fused", [True, False], ids=["fused", "per-metric"])
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", ["single-shot", "chunked", "streamed"])
def test_execution_grid_identical(tensor, reference, fused, backend, mode):
    pipe = qa.pipeline().metrics(ALL_METRICS).fused(fused).backend(backend)
    if mode == "chunked":
        pipe = pipe.chunked(8)
    data = iter(tensor.chunks(8)) if mode == "streamed" else tensor
    res = pipe.run(data)
    assert set(res.values) == set(reference.values)
    for k, v in reference.values.items():
        assert res.values[k] == pytest.approx(v, abs=1e-9), k
    # HLL estimates derive from registers alone: exact equality here means
    # the sketch register state agrees across every strategy
    assert res.sketch_estimates == reference.sketch_estimates
    n_chunks = 1 if mode == "single-shot" else 8
    if mode != "single-shot":
        assert res.exec_stats is not None
        assert res.exec_stats.chunks_total == 8
        assert len(res.exec_stats.chunk_eval_seconds) == 8
    assert res.passes == n_chunks * _expected_passes_per_chunk(
        pipe.evaluator())


def test_sketch_registers_bit_identical_across_backends(tensor):
    """Not just the estimates: the raw HLL register banks must agree
    bit-for-bit across backends and between single-shot and merged-chunk
    execution."""
    from repro.core.evaluator import QualityEvaluator
    ref_regs = None
    for backend in BACKENDS:
        ev = QualityEvaluator(ALL_METRICS, fused=True, backend=backend)
        _, regs = ev.eval_chunk(tensor)
        assert set(regs) == {"spo", "p"}
        if ref_regs is None:
            ref_regs = regs
        else:
            for k in ref_regs:
                np.testing.assert_array_equal(regs[k], ref_regs[k],
                                              f"{backend}:{k}")
        # chunk-merged registers ≡ single-shot registers (max-merge)
        state = ev.chunk_state_init()
        for cid, c in enumerate(tensor.chunks(5)):
            counts, cregs = ev.eval_chunk(c)
            ev.merge_chunk(state, cid, counts, cregs)
        for k in ref_regs:
            np.testing.assert_array_equal(state["sketches"][k], ref_regs[k],
                                          f"{backend}:merged:{k}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_registers_renumbering_invariant_across_backends(backend):
    """Plane layout v2: sketches hash term *content* (COL_*_HASH), not
    ids.  Reordering the triples renumbers every term id (different
    first-appearance order) yet must leave values AND register banks
    bit-identical — the invariant the store's edit-local mutation/delete
    reuse rests on.  Deterministic companion to the hypothesis
    permutation property in test_store_property.py."""
    from repro.rdf import parse_encode
    from repro.rdf.triple_tensor import COL_S, COL_S_HASH
    text = bsbm_ntriples(50, seed=14)
    lines = text.strip().split("\n")
    shuffled = "\n".join(lines[::-1]) + "\n"
    # non-vacuity: the reordering really does renumber ids (id planes
    # differ under line-reversal) while content hashes follow their terms
    a, b = parse_encode(text), parse_encode(shuffled)
    assert not np.array_equal(a.planes[:, COL_S], b.planes[::-1, COL_S])
    np.testing.assert_array_equal(a.planes[:, COL_S_HASH],
                                  b.planes[::-1, COL_S_HASH])
    p = qa.pipeline().metrics(ALL_METRICS).backend(backend)
    ref, res = p.run(text), p.run(shuffled)
    assert res.values == ref.values
    assert set(res.registers) == {"spo", "p"}
    for k in ref.registers:
        np.testing.assert_array_equal(res.registers[k], ref.registers[k],
                                      f"{backend}:{k}")


def test_fused_scan_is_one_pass(tensor):
    """THE acceptance criterion: with sketch metrics enabled the
    fused_scan backend performs exactly one pass over the planes —
    measured by the kernel-level scan counter, not inferred."""
    from repro.core.evaluator import QualityEvaluator
    ev = QualityEvaluator(ALL_METRICS, fused=True, backend="fused_scan")
    assert len(ev._all_sketch_specs()) == 2  # sketches ARE enabled
    assert ev.passes_per_chunk == 1
    # ... while the two-kernel pallas path pays 1 + S
    ev2 = QualityEvaluator(ALL_METRICS, fused=True, backend="pallas")
    assert ev2.passes_per_chunk == 3
    ev3 = QualityEvaluator(ALL_METRICS, fused=True, backend="jnp")
    assert ev3.passes_per_chunk == 3
    # single-shot result reports the measured number
    res = qa.assess(tensor, metrics=ALL_METRICS, backend="fused_scan")
    assert res.passes == 1


# --- async pipelined chunk executor ------------------------------------------

def test_pipelined_executor_bit_identical(tensor):
    sync = qa.pipeline().metrics(ALL_METRICS).chunked(8).run(tensor)
    pipelined = qa.pipeline().metrics(ALL_METRICS).chunked(8) \
                  .pipelined().run(tensor)
    assert pipelined.values == sync.values
    assert pipelined.sketch_estimates == sync.sketch_estimates
    assert pipelined.counts == sync.counts
    assert pipelined.exec_stats.mode == "pipelined"
    assert sync.exec_stats.mode == "sync"
    assert pipelined.exec_stats.chunks_total == 8
    assert len(pipelined.exec_stats.chunk_eval_seconds) == 8
    assert pipelined.exec_stats.wall_seconds > 0
    # streamed (lazy iterable) ingest through the async executor
    streamed = qa.pipeline().metrics(ALL_METRICS).pipelined() \
                 .run(iter(tensor.chunks(6)))
    assert streamed.values == sync.values
    assert streamed.exec_stats.chunks_total == 6


def test_pipelined_fault_tolerance_and_resume(tensor):
    """Retries, coordinator crash, and checkpoint/resume behave exactly as
    in the sequential loop when the executor is pipelined."""
    from repro.core.evaluator import QualityEvaluator
    from repro.dist import ChunkScheduler, FaultInjector, WorkerFailure
    ev = QualityEvaluator(PAPER_METRICS, fused=True, backend="jnp")
    ref = ev.assess(tensor)
    with tempfile.TemporaryDirectory() as d:
        sched = ChunkScheduler(ev, n_chunks=10, checkpoint_dir=d,
                               checkpoint_every=4, prefetch=1)
        faults = FaultInjector(fail_chunks={1: 2}, crash_after_merges=7)
        with pytest.raises(WorkerFailure):
            sched.run(tensor, faults=faults)
        sched2 = ChunkScheduler(ev, n_chunks=10, checkpoint_dir=d,
                                checkpoint_every=4, prefetch=1)
        res, stats = sched2.run(tensor)
        assert stats.resumed_from is not None
        assert stats.attempts < 10, "resume must skip completed chunks"
        assert stats.mode == "pipelined"
    for k, v in ref.values.items():
        assert res.values[k] == pytest.approx(v, abs=1e-9), k


def test_pipelined_retries_materialize_failures(tensor):
    """Dispatch is async, so real worker failures surface at host sync;
    the pipelined executor must re-dispatch and retry there just like the
    sequential loop retries the whole eval."""
    from repro.core.evaluator import QualityEvaluator
    from repro.dist import ChunkScheduler, WorkerFailure
    ev = QualityEvaluator(PAPER_METRICS, fused=True, backend="jnp")
    ref = ev.assess(tensor)
    boom = {"left": 2}
    orig = ev.materialize_chunk

    def flaky(outs):
        if boom["left"]:
            boom["left"] -= 1
            raise WorkerFailure("host sync died")
        return orig(outs)

    ev.materialize_chunk = flaky  # instance attr shadows the staticmethod
    try:
        res, stats = ChunkScheduler(ev, n_chunks=6, prefetch=1).run(tensor)
        # a chunk that NEVER recovers aborts after the same per-chunk
        # failure budget as the sequential loop (no free extra attempt)
        boom["left"] = 10**9
        with pytest.raises(WorkerFailure):
            ChunkScheduler(ev, n_chunks=6, prefetch=1,
                           max_attempts=4).run(tensor)
        assert boom["left"] == 10**9 - 4
    finally:
        del ev.materialize_chunk
    assert stats.retries == 2
    for k, v in ref.values.items():
        assert res.values[k] == pytest.approx(v, abs=1e-9), k


def test_straggler_detection_flags_slow_chunks(tensor):
    """The scheduler consumes its own chunk_eval_seconds: a chunk slower
    than straggler_factor × the running median is flagged on
    ChunkStats.stragglers and reported in one warning line."""
    from repro.core.evaluator import QualityEvaluator
    from repro.dist import ChunkScheduler, FaultInjector
    ev = QualityEvaluator(PAPER_METRICS, fused=True, backend="jnp")
    ref = ev.assess(tensor)
    sched = ChunkScheduler(ev, n_chunks=8, straggler_factor=3.0)
    faults = FaultInjector(slow_chunks={5: 0.6})
    with pytest.warns(RuntimeWarning, match="straggler"):
        res, stats = sched.run(tensor, faults=faults)
    assert 5 in stats.stragglers
    assert len(stats.chunk_eval_seconds) == 8
    # detection never perturbs results
    for k, v in ref.values.items():
        assert res.values[k] == pytest.approx(v, abs=1e-9), k
    # factor=0 disables detection
    _, stats2 = ChunkScheduler(ev, n_chunks=8, straggler_factor=0).run(
        tensor, faults=FaultInjector(slow_chunks={5: 0.3}))
    assert stats2.stragglers == []


def test_speculative_reexecution_slow_copy_loses(tensor):
    """speculate=True: a chunk whose primary eval outlives the live
    straggler threshold gets a backup copy dispatched; the backup (not
    slowed — a slow *worker*, not a slow partition) finishes first and
    wins.  The merge is idempotent per chunk id, so the abandoned slow
    copy cannot corrupt anything, and results match the fault-free run
    bit-for-bit."""
    from repro.core.evaluator import QualityEvaluator
    from repro.dist import ChunkScheduler, FaultInjector
    ev = QualityEvaluator(PAPER_METRICS, fused=True, backend="jnp")
    ref = ev.assess(tensor)
    sched = ChunkScheduler(ev, n_chunks=8, straggler_factor=3.0,
                           speculate=True)
    # chunk 5 is slow on its FIRST attempt only: the speculative backup
    # runs at full speed and must complete long before the 2s sleep ends
    faults = FaultInjector(slow_chunks_once={5: 2.0})
    t0 = time.perf_counter()
    with pytest.warns(RuntimeWarning, match="straggler"):
        res, stats = sched.run(tensor, faults=faults)
    assert 5 in stats.speculated
    assert 5 in stats.stragglers          # live-flagged, not just post-hoc
    assert stats.speculation_wins >= 1    # the slow copy lost
    assert time.perf_counter() - t0 < 2.0, "run must not wait out the sleep"
    assert res.values == ref.values
    assert res.counts == ref.counts
    # speculation off: the same fault stalls the whole run
    _, stats2 = ChunkScheduler(ev, n_chunks=8, straggler_factor=3.0,
                               speculate=False).run(
        tensor, faults=FaultInjector(slow_chunks_once={5: 0.2}))
    assert stats2.speculated == [] and stats2.speculation_wins == 0


def test_pipelined_ingest_error_propagates(tensor):
    def bad_stream():
        yield tensor.chunks(4)[0]
        raise RuntimeError("exploding tokenizer")
    with pytest.raises(RuntimeError, match="exploding tokenizer"):
        qa.pipeline().metrics("paper").pipelined().run(bad_stream())


def test_chunked_checkpointing_writes_state(tensor):
    with tempfile.TemporaryDirectory() as d:
        res = qa.assess(tensor, metrics="paper", chunks=8,
                        checkpoint_dir=d, checkpoint_every=4)
        assert res.exec_stats.checkpoints_written >= 1
        assert any(n.startswith("step_") for n in os.listdir(d))


def test_completed_run_always_checkpoints(tensor):
    """Even when n_chunks never aligns with checkpoint_every, a completed
    run must persist its final state (else checkpointing silently no-ops
    and a re-run rescans everything)."""
    with tempfile.TemporaryDirectory() as d:
        res = qa.assess(tensor, metrics="paper", chunks=6,
                        checkpoint_dir=d)  # default checkpoint_every=8 > 6
        assert res.exec_stats.checkpoints_written == 1
        res2 = qa.assess(tensor, metrics="paper", chunks=6,
                         checkpoint_dir=d)
        assert res2.exec_stats.resumed_from == 6
        assert res2.exec_stats.attempts == 0
        assert res2.values == res.values


# --- fluent builder ----------------------------------------------------------

def test_pipeline_is_immutable():
    p1 = qa.pipeline().metrics("paper")
    p2 = p1.backend("pallas").chunked(4, checkpoint_dir="/tmp/x")
    assert p1.exec.backend == "jnp" and p1.exec.chunks == 0
    assert p2.exec.backend == "pallas" and p2.exec.chunks == 4
    assert p2.metric_names == p1.metric_names == PAPER_METRICS
    assert p2.single_shot().exec.chunks == 0
    with pytest.raises(dataclasses.FrozenInstanceError):
        p1.exec = None


def test_pipeline_validation():
    with pytest.raises(ValueError, match="backend"):
        qa.pipeline().backend("tpu9000")
    with pytest.raises(ValueError, match="unknown metrics"):
        qa.pipeline().metrics("paper,NOT_A_METRIC")
    with pytest.raises(ValueError, match="no metrics"):
        qa.pipeline().metrics("")
    # every construction path validates, not just the fluent method
    with pytest.raises(ValueError, match="backend"):
        qa.ExecutionConfig(backend="Pallas")
    with pytest.raises(ValueError, match="prefetch"):
        qa.ExecutionConfig(prefetch=-1)


def test_incompatible_checkpoint_rejected(tensor):
    """Resuming a checkpoint written under different n_chunks or metrics
    would merge stale counts for different data slices — must raise."""
    with tempfile.TemporaryDirectory() as d:
        qa.assess(tensor, metrics="paper", chunks=8, checkpoint_dir=d,
                  checkpoint_every=4)
        with pytest.raises(ValueError, match="incompatible"):
            qa.assess(tensor, metrics="paper", chunks=4, checkpoint_dir=d)
        with pytest.raises(ValueError, match="incompatible"):
            qa.assess(tensor, metrics="L1,I2", chunks=8, checkpoint_dir=d)
        # a different dataset must not resume another dataset's state
        other = synth_encoded(N + 500, seed=99)
        with pytest.raises(ValueError, match="incompatible"):
            qa.assess(other, metrics="paper", chunks=8, checkpoint_dir=d)
        # the matching configuration still resumes
        res = qa.assess(tensor, metrics="paper", chunks=8, checkpoint_dir=d)
        assert res.exec_stats.resumed_from == 8
        assert res.exec_stats.attempts == 0


def test_metric_selection_forms():
    assert qa.pipeline().metrics("paper").metric_names == PAPER_METRICS
    assert qa.pipeline().metrics("L1, I2").metric_names == ("L1", "I2")
    assert qa.pipeline().metrics(["U1", "CN2"]).metric_names == ("U1", "CN2")
    m = M.REGISTRY["RC1"]
    assert qa.pipeline().metrics([m]).metric_names == ("RC1",)
    assert set(ALL_METRICS) <= set(qa.pipeline().metrics("all").metric_names)
    # an unregistered Metric object is accepted and registered on the fly
    try:
        um = qa.ratio_metric("X_UNREG", num=qa.is_blank("s"),
                             auto_register=False)
        assert "X_UNREG" not in M.REGISTRY
        assert qa.pipeline().metrics(["L1", um]).metric_names == \
            ("L1", "X_UNREG")
        assert M.REGISTRY["X_UNREG"] is um
        # ... but a name collision with a different definition is refused
        impostor = qa.ratio_metric("L1", num=qa.is_blank("s"),
                                   auto_register=False)
        with pytest.raises(ValueError, match="already registered"):
            qa.pipeline().metrics([impostor])
        assert M.REGISTRY["L1"].description.startswith("Detection")
    finally:
        qa.unregister("X_UNREG")


def test_describe_mentions_strategy():
    d = qa.pipeline().metrics("paper").backend("pallas").per_metric() \
          .chunked(8).describe()
    assert "pallas" in d and "per-metric" in d and "chunked×8" in d
    d2 = qa.pipeline().backend("fused_scan").chunked(4).pipelined(2) \
           .describe()
    assert "fused_scan" in d2 and "async×2" in d2


def test_describe_fully_determines_execution():
    """repr must surface hll_p and the incremental/store mode — two
    configs that execute differently must describe differently."""
    assert "hll_p=12" in qa.pipeline().describe()       # the default
    assert "hll_p=9" in qa.pipeline().hll(9).describe()
    d = qa.pipeline().incremental("/tmp/qstore", segment_bytes=4096) \
          .pipelined().describe()
    assert "incremental@/tmp/qstore" in d
    assert "seg=4096B" in d and "async×1" in d
    # incremental replaces the chunked/streamed mode in the description
    d2 = qa.pipeline().chunked(8).incremental("/tmp/qstore").describe()
    assert "chunked" not in d2
    # ... and single_shot() clears the store
    assert "incremental" not in (qa.pipeline().incremental("/tmp/qstore")
                                 .single_shot().describe())


# --- polymorphic ingest ------------------------------------------------------

BSBM_BASE = ("http://bsbm.example.org/",)


def test_ingest_nt_text_and_path_and_tensor(tmp_path):
    nt = bsbm_ntriples(30, seed=1)
    pipe = qa.pipeline().metrics("paper").base(*BSBM_BASE)
    from_text = pipe.run(nt)
    path = tmp_path / "data.nt"
    path.write_text(nt)
    from_path = pipe.run(str(path))
    from_pathlike = pipe.run(path)
    from_tensor = pipe.run(
        __import__("repro.rdf", fromlist=["encode_ntriples"])
        .encode_ntriples(nt, base_namespaces=BSBM_BASE))
    for other in (from_path, from_pathlike, from_tensor):
        assert other.values == from_text.values
        assert other.n_triples == from_text.n_triples


def test_ingest_missing_path_raises():
    with pytest.raises(FileNotFoundError):
        qa.pipeline().run("no_such_file.nt")
    # a missing path containing a space must not be parsed as NT text
    with pytest.raises(FileNotFoundError):
        qa.pipeline().run("my data/no_such_file.nt")
    # ... but a single statement-shaped line is content
    res = qa.pipeline().metrics("L1").run(
        "<http://a/s> <http://purl.org/dc/terms/license> <http://a/l> .")
    assert res.n_triples == 1 and res.values["L1"] == 1.0


def test_metric_alias_mixes_with_names():
    p = qa.pipeline().metrics("paper,CS1")
    assert p.metric_names == PAPER_METRICS + ("CS1",)
    assert qa.pipeline().metrics("L1,L1,paper").metric_names == PAPER_METRICS


def test_streaming_ingest_matches_whole(tensor):
    """An iterable of chunks (tensors or NT text) is a streaming dataset."""
    whole = qa.assess(tensor, metrics="paper")
    parts = tensor.chunks(6)
    streamed = qa.pipeline().metrics("paper").run(iter(parts))
    assert streamed.exec_stats.chunks_total == 6
    for k, v in whole.values.items():
        assert streamed.values[k] == pytest.approx(v, abs=1e-9), k
    # text chunks: split an N-Triples document line-wise
    nt = bsbm_ntriples(20, seed=8)
    lines = nt.splitlines()
    half = len(lines) // 2
    text_chunks = ["\n".join(lines[:half]), "\n".join(lines[half:])]
    pipe = qa.pipeline().metrics("paper").base(*BSBM_BASE)
    streamed_text = pipe.run(text_chunks)
    whole_text = pipe.run(nt)
    for k in ("I2", "U1", "RC1", "CN2"):
        assert streamed_text.values[k] == pytest.approx(
            whole_text.values[k], abs=1e-9), k


# --- declarative custom metrics (LQML-style) ---------------------------------

def test_declarative_builders_register_and_fuse(tensor):
    try:
        qa.ratio_metric("X_LIT", num=qa.is_literal("o"),
                        dimension="test")
        qa.exists_metric("X_HAS_BLANK", qa.is_blank("s"))
        qa.count_metric("X_N_URI_S", qa.is_uri("s"))

        @qa.qap_metric("X_URI_BALANCE", {"s": qa.is_uri("s"),
                                         "o": qa.is_uri("o"),
                                         "total": qa.valid_triple()})
        def _balance(c):
            return (c["s"] - c["o"]) / max(c["total"], 1)

        names = PAPER_METRICS + ("X_LIT", "X_HAS_BLANK", "X_N_URI_S",
                                 "X_URI_BALANCE")
        res = qa.assess(tensor, metrics=names)
        lit = res.counts["X_LIT"]
        assert res.values["X_LIT"] == pytest.approx(
            lit["num"] / lit["den"])
        assert res.values["X_HAS_BLANK"] in (0.0, 1.0)
        assert 0 < res.values["X_N_URI_S"] <= float(len(tensor))
        assert res.values["X_N_URI_S"] == float(res.counts["X_N_URI_S"]["hit"])
        # the user metrics share count(valid) with the built-in ratios
        p = plan(M.get_metrics(names))
        assert sum(e == M.valid_triple() for e in p.exprs) == 1
        # user metrics run through "all" too
        assert "X_LIT" in qa.pipeline().metrics("all").metric_names
    finally:
        for n in ("X_LIT", "X_HAS_BLANK", "X_N_URI_S", "X_URI_BALANCE"):
            qa.unregister(n)
    assert "X_LIT" not in M.REGISTRY


def test_register_as_decorator_on_factory():
    try:
        @M.register
        def _make():
            return M.Metric(
                name="X_FACTORY", dimension="test", description="d",
                counters=(("hit", qa.valid_triple()),),
                finalize=lambda c: float(c["hit"]))
        assert "X_FACTORY" in M.REGISTRY
    finally:
        qa.unregister("X_FACTORY")


# --- shim: legacy QualityEvaluator routes through the pipeline ---------------

def test_evaluator_shim_matches_pipeline(tensor):
    legacy = QualityEvaluator(PAPER_METRICS, fused=True).assess(tensor)
    new = qa.pipeline().metrics("paper").run(tensor)
    assert legacy.values == new.values


# --- engine cache: mesh identity is structural, not object -------------------

def test_evaluator_cache_hits_across_rebuilt_meshes(tensor):
    """A service or benchmark building a fresh (but structurally equal)
    mesh per call must NOT recompile the engine: the evaluator cache keys
    on (axis names, device grid shape, device ids), not the Mesh object."""
    import jax
    from repro.qa.pipeline import _evaluator_for

    _evaluator_for.cache_clear()
    mesh_a = jax.make_mesh((1,), ("data",))
    mesh_b = jax.make_mesh((1,), ("data",))
    ev_a = qa.pipeline().metrics("paper").shard(mesh_a).evaluator()
    ev_b = qa.pipeline().metrics("paper").shard(mesh_b).evaluator()
    assert ev_a is ev_b, "rebuilt mesh must hit the engine cache"
    info = _evaluator_for.cache_info()
    assert info.misses == 1 and info.hits >= 1
    # a structurally DIFFERENT mesh is a different engine
    mesh_c = jax.make_mesh((1,), ("rows",))
    ev_c = qa.pipeline().metrics("paper").shard(mesh_c).evaluator()
    assert ev_c is not ev_a
    # and the sharded engine still agrees with the local one
    res = ev_a.assess(tensor)
    ref = qa.pipeline().metrics("paper").run(tensor)
    assert res.values == ref.values

"""DQV report emission: one measurement per metric, properly namespaced
keys, deterministic output, N-Triples that re-parse through our own parser
(dimension + provenance triples included), and the quality history."""
import json
import os

import pytest

from repro.core import ALL_METRICS, PAPER_METRICS, QualityEvaluator, report
from repro.core.metrics import REGISTRY
from repro.rdf import synth_encoded
from repro.rdf.parser import parse_ntriples

TS = "2020-01-01T00:00:00+00:00"


@pytest.fixture(scope="module")
def result():
    return QualityEvaluator(ALL_METRICS, fused=True).assess(
        synth_encoded(4000, seed=17))


def test_dqv_one_measurement_per_metric(result):
    dqv = report.to_dqv(result, dataset_uri="urn:test:ds", computed_on=TS)
    assert len(dqv["measurements"]) == len(ALL_METRICS)
    measured = {m[report.DQV + "isMeasurementOf"]["@id"]
                for m in dqv["measurements"]}
    assert measured == {f"urn:repro:metric:{n}" for n in ALL_METRICS}
    for m in dqv["measurements"]:
        assert m[report.DQV + "computedOn"]["@id"] == "urn:test:ds"
        assert isinstance(m[report.DQV + "value"], float)


def test_dqv_keys_are_namespaced(result):
    """Every property key carries its vocabulary namespace — no bare
    `inDimension`/`description`/`generatedAtTime` keys mixed in with
    namespaced ones."""
    dqv = report.to_dqv(result, computed_on=TS)
    for m in dqv["measurements"]:
        bare = [k for k in m if not k.startswith(("@", "http://"))]
        assert bare == [], f"un-namespaced keys: {bare}"
        assert m[report.DQV + "inDimension"]["@id"].startswith(
            "urn:repro:dimension:")
        assert m[report.DCT + "description"]
        t = m[report.PROV + "generatedAtTime"]
        assert t == {"@value": TS, "@type": report.XSD + "dateTime"}
    # dimensions come from the registry taxonomy
    dims = {m[report.DQV + "inDimension"]["@id"]
            for m in dqv["measurements"]}
    assert dims == {f"urn:repro:dimension:{REGISTRY[n].dimension}"
                    for n in ALL_METRICS}


def test_dqv_deterministic_under_fixed_timestamp(result):
    a = report.to_dqv(result, computed_on=TS)
    b = report.to_dqv(result, computed_on=TS)
    assert a == b
    assert report.to_json(result, computed_on=TS) == \
        report.to_json(result, computed_on=TS)
    # and json round-trips
    assert json.loads(report.to_json(result, computed_on=TS)) == a


def test_ntriples_report_reparses(result):
    nt = report.to_ntriples(result, dataset_uri="urn:test:ds",
                            computed_on=TS)
    triples = parse_ntriples(nt)
    # no malformed lines (the parser flags them with a sentinel IRI)
    assert all(s.value != "urn:repro:parse-error" for s, _, _ in triples)
    # one dqv:value triple per metric, carried as a typed double literal
    values = [(s, p, o) for s, p, o in triples
              if p.value == report.DQV + "value"]
    assert len(values) == len(result.values)
    for s, _, o in values:
        assert s.kind == "blank"
        assert o.kind == "literal"
        assert o.datatype == "http://www.w3.org/2001/XMLSchema#double"
        float(o.value)  # parses as a number
    # every measurement links back to the dataset
    linked = {s.value for s, p, o in triples
              if p.value == report.DQV + "computedOn"
              and o.value == "urn:test:ds"}
    assert len(linked) == len(result.values)


def test_ntriples_report_has_dimension_and_timestamp(result):
    """The N-Triples serialization must describe the same graph as the
    JSON-LD: dimension + provenance triples were previously omitted."""
    nt = report.to_ntriples(result, computed_on=TS)
    triples = parse_ntriples(nt)
    dims = [(s, o) for s, p, o in triples
            if p.value == report.DQV + "inDimension"]
    assert len(dims) == len(result.values)
    for _, o in dims:
        assert o.kind == "iri" and o.value.startswith(
            "urn:repro:dimension:")
    times = [o for s, p, o in triples
             if p.value == report.PROV + "generatedAtTime"]
    assert len(times) == len(result.values)
    for o in times:
        assert o.kind == "literal"
        assert o.datatype == report.XSD + "dateTime"
        assert o.value == TS
    # the NT graph also carries the metric descriptions the JSON-LD has
    descs = {o.value for s, p, o in triples
             if p.value == report.DCT + "description"}
    assert descs == {REGISTRY[n].description for n in result.values}


def test_ntriples_report_deterministic(result):
    assert report.to_ntriples(result, computed_on=TS) == \
        report.to_ntriples(result, computed_on=TS)
    lines = report.to_ntriples(result, computed_on=TS).strip().splitlines()
    assert len(lines) == 6 * len(result.values)


# --- quality history ----------------------------------------------------------

def test_history_append_load_roundtrip(result, tmp_path):
    path = tmp_path / "history.jsonl"
    e1 = report.append_history(path, result, computed_on=TS,
                               dataset_uri="urn:test:ds")
    e2 = report.append_history(path, result,
                               computed_on="2020-01-02T00:00:00+00:00")
    loaded = report.load_history(path)
    assert loaded == [e1, e2]
    assert loaded[0]["values"] == {k: float(v)
                                   for k, v in result.values.items()}
    assert loaded[0]["nTriples"] == result.n_triples


def test_history_skips_torn_tail(result, tmp_path):
    path = tmp_path / "history.jsonl"
    report.append_history(path, result, computed_on=TS)
    with open(path, "a") as f:
        f.write('{"values": {"L1": 1.0}, "trunc')  # torn final append
    loaded = report.load_history(path)
    assert len(loaded) == 1
    assert report.load_history(tmp_path / "missing.jsonl") == []


def test_to_dqv_history_trend_report(result, tmp_path):
    path = tmp_path / "history.jsonl"
    report.append_history(path, result, computed_on=TS)
    # second snapshot with one metric nudged
    import dataclasses
    nudged = dataclasses.replace(
        result, values={**result.values,
                        "L1": result.values["L1"] + 0.25})
    report.append_history(path, nudged,
                          computed_on="2020-01-02T00:00:00+00:00")
    trend = report.to_dqv_history(path)
    assert trend["snapshots"] == 2
    m = trend["metrics"]["L1"]
    assert m["values"] == [result.values["L1"], result.values["L1"] + 0.25]
    assert m["delta"] == pytest.approx(0.25)
    assert m["latest"] == pytest.approx(result.values["L1"] + 0.25)
    for name, mm in trend["metrics"].items():
        if name != "L1":
            assert mm["delta"] == 0.0
    # an entry list works the same as a path
    assert report.to_dqv_history(report.load_history(path)) == trend


def test_to_dqv_history_aligns_mixed_metric_sets():
    """Snapshots may measure different metric sets (engine reconfigured
    between runs): series stay aligned to the snapshot axis with None for
    absent values, and delta only compares the last two ADJACENT
    snapshots that both carry the metric."""
    entries = [
        {"generatedAtTime": "t0", "values": {"A": 1.0, "B": 5.0}},
        {"generatedAtTime": "t1", "values": {"A": 2.0}},
        {"generatedAtTime": "t2", "values": {"A": 4.0, "C": 9.0}},
    ]
    trend = report.to_dqv_history(entries)
    assert trend["snapshots"] == 3
    assert trend["metrics"]["A"]["values"] == [1.0, 2.0, 4.0]
    assert trend["metrics"]["A"]["delta"] == 2.0
    assert trend["metrics"]["B"]["values"] == [5.0, None, None]
    assert trend["metrics"]["B"]["delta"] == 0.0    # absent from the tail
    assert trend["metrics"]["B"]["latest"] == 5.0
    assert trend["metrics"]["C"]["values"] == [None, None, 9.0]
    assert trend["metrics"]["C"]["delta"] == 0.0    # no adjacent pair
    assert trend["metrics"]["C"]["min"] == trend["metrics"]["C"]["max"] == 9.0


def test_to_dqv_execution_provenance(result, tmp_path):
    """Service consumers read reuse provenance straight off the JSON
    report (no exec_stats side channel): nTriples, passes, and the key
    segment-store fields.  Single-shot results (no scheduler stats) have
    no execStats key, and the NT serialization carries measurement
    triples only — unchanged."""
    # single-shot result: no exec stats, no key
    dqv = report.to_dqv(result, computed_on=TS)
    assert dqv["nTriples"] == result.n_triples
    assert dqv["passes"] == result.passes
    assert "execStats" not in dqv

    # incremental run: execStats carries the reuse accounting
    from repro import qa
    from repro.rdf import bsbm_ntriples
    base = ("http://bsbm.example.org/",)
    data = bsbm_ntriples(60, seed=5)
    store = os.fspath(tmp_path / "st")
    qa.assess(data, metrics="paper", base=base, store=store,
              segment_bytes=8192)
    warm = qa.assess(data + bsbm_ntriples(4, seed=50), metrics="paper",
                     base=base, store=store, segment_bytes=8192)
    dqv = report.to_dqv(warm, computed_on=TS)
    es = dqv["execStats"]
    assert es["segments_reused"] == warm.exec_stats.segments_reused >= 1
    assert es["segments_rescanned"] == warm.exec_stats.segments_rescanned
    assert es["bytes_total"] == warm.exec_stats.bytes_total
    assert es["bytes_rescanned"] == warm.exec_stats.bytes_rescanned
    assert es["mode"] == "incremental"
    assert all(isinstance(v, (int, str)) for v in es.values())
    json.loads(report.to_json(warm, computed_on=TS))  # serializable
    # single-device runs carry no devices key; mesh runs surface the
    # shard count in the provenance
    assert "devices" not in es
    warm.exec_stats.devices = 8
    assert report.to_dqv(warm, computed_on=TS)["execStats"]["devices"] == 8
    warm.exec_stats.devices = 1
    # NT form unchanged: exactly the 6 measurement triples per metric
    from repro.rdf.parser import parse_ntriples
    nt = report.to_ntriples(warm, computed_on=TS)
    assert len(parse_ntriples(nt)) == 6 * len(warm.values)

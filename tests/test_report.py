"""DQV report emission: one measurement per metric, deterministic output,
and N-Triples that re-parse through our own parser."""
import json

import pytest

from repro.core import ALL_METRICS, PAPER_METRICS, QualityEvaluator, report
from repro.rdf import synth_encoded
from repro.rdf.parser import parse_ntriples

TS = "2020-01-01T00:00:00+00:00"


@pytest.fixture(scope="module")
def result():
    return QualityEvaluator(ALL_METRICS, fused=True).assess(
        synth_encoded(4000, seed=17))


def test_dqv_one_measurement_per_metric(result):
    dqv = report.to_dqv(result, dataset_uri="urn:test:ds", computed_on=TS)
    assert len(dqv["measurements"]) == len(ALL_METRICS)
    measured = {m[report.DQV + "isMeasurementOf"]["@id"]
                for m in dqv["measurements"]}
    assert measured == {f"urn:repro:metric:{n}" for n in ALL_METRICS}
    for m in dqv["measurements"]:
        assert m[report.DQV + "computedOn"]["@id"] == "urn:test:ds"
        assert m["generatedAtTime"] == TS
        assert isinstance(m[report.DQV + "value"], float)
        assert m["inDimension"] and m["description"]


def test_dqv_deterministic_under_fixed_timestamp(result):
    a = report.to_dqv(result, computed_on=TS)
    b = report.to_dqv(result, computed_on=TS)
    assert a == b
    assert report.to_json(result, computed_on=TS) == \
        report.to_json(result, computed_on=TS)
    # and json round-trips
    assert json.loads(report.to_json(result, computed_on=TS)) == a


def test_ntriples_report_reparses(result):
    nt = report.to_ntriples(result, dataset_uri="urn:test:ds")
    triples = parse_ntriples(nt)
    # no malformed lines (the parser flags them with a sentinel IRI)
    assert all(s.value != "urn:repro:parse-error" for s, _, _ in triples)
    # one dqv:value triple per metric, carried as a typed double literal
    values = [(s, p, o) for s, p, o in triples
              if p.value == report.DQV + "value"]
    assert len(values) == len(result.values)
    for s, _, o in values:
        assert s.kind == "blank"
        assert o.kind == "literal"
        assert o.datatype == "http://www.w3.org/2001/XMLSchema#double"
        float(o.value)  # parses as a number
    # every measurement links back to the dataset
    linked = {s.value for s, p, o in triples
              if p.value == report.DQV + "computedOn"
              and o.value == "urn:test:ds"}
    assert len(linked) == len(result.values)


def test_ntriples_report_deterministic(result):
    assert report.to_ntriples(result) == report.to_ntriples(result)
    lines = report.to_ntriples(result).strip().splitlines()
    assert len(lines) == 3 * len(result.values)

"""repro.store — incremental assessment against the persistent segment
store.

The contract under test: for ANY edit sequence (append / delete /
in-place mutation), an incremental ``run()`` against the store produces
metric values AND HLL register banks bit-identical to a cold full
assessment of the final bytes — across every backend — while unchanged
segments are served from frozen state (no kernel passes).  Corrupt or
truncated store files must degrade to a rescan of the affected segments
only, never to a wrong answer.
"""
import io
import json
import os

import numpy as np
import pytest

from repro import qa
from repro.core import ALL_METRICS
from repro.rdf import bsbm_ntriples
from repro.store import (SegmentStore, engine_signature, fingerprint,
                         iter_segments, split_segments)

BASE = ("http://bsbm.example.org/",)
SEG = 16384         # small target → many segments on the test corpus


def corpus(n_products=300, seed=11) -> bytes:
    return bsbm_ntriples(n_products, seed=seed).encode()


def pipe(backend="jnp", store=None):
    p = qa.pipeline().metrics(ALL_METRICS).backend(backend).base(*BASE)
    if store is not None:
        p = p.incremental(store, segment_bytes=SEG)
    return p


def assert_bit_identical(inc, cold):
    assert inc.values == cold.values
    assert inc.n_triples == cold.n_triples
    assert inc.sketch_estimates == cold.sketch_estimates
    assert set(inc.registers) == set(cold.registers)
    for k in cold.registers:
        np.testing.assert_array_equal(inc.registers[k], cold.registers[k],
                                      f"registers:{k}")


# --- segmenter ----------------------------------------------------------------

def test_segments_partition_input_and_align_to_lines():
    data = corpus(300)
    segs = split_segments(data, SEG)
    assert b"".join(segs) == data
    assert len(segs) > 4
    assert all(s.endswith(b"\n") for s in segs[:-1])
    # streaming over a file object decides identical boundaries
    assert list(iter_segments(io.BytesIO(data), SEG)) == segs


def test_segmentation_edit_locality():
    data = corpus(300)
    known = {fingerprint(s) for s in split_segments(data, SEG)}

    appended = data + bsbm_ntriples(3, seed=99).encode()
    changed = [s for s in split_segments(appended, SEG)
               if fingerprint(s) not in known]
    assert len(changed) <= 2  # the tail segment + possibly one new one

    mid = data.find(b"\n", len(data) // 2) + 1
    end = data.find(b"\n", mid) + 1
    mutated = data[:mid] + b"<http://x/s> <http://x/p> <http://x/o> .\n" \
        + data[end:]
    changed = [s for s in split_segments(mutated, SEG)
               if fingerprint(s) not in known]
    assert len(changed) <= 2  # only the segment(s) framing the edit


def test_tiny_segment_targets_keep_edit_locality():
    """Small targets narrow the candidate mask below the magic value; the
    masked comparison must still produce content-defined cuts (a never-
    matching test would silently degrade to fixed-size splitting and void
    the reuse contract)."""
    data = corpus(300)
    for target in (1024, 4096):
        segs = split_segments(data, target)
        assert b"".join(segs) == data
        known = {fingerprint(s) for s in segs}
        edited = b"<http://x/s> <http://x/p> <http://x/o> .\n" + data
        changed = [s for s in split_segments(edited, target)
                   if fingerprint(s) not in known]
        assert len(changed) <= 2, f"target={target}: no edit locality"


def test_newline_free_input_degrades_gracefully():
    blob = b"x" * (1 << 20)
    segs = split_segments(blob, 4096)
    assert b"".join(segs) == blob  # cannot cut: one jumbo segment


# --- exactness ----------------------------------------------------------------

def test_cold_then_warm_is_bit_identical(tmp_path):
    data = corpus()
    cold = pipe().run(data.decode())
    inc = pipe(store=tmp_path / "st").run(data.decode())
    assert_bit_identical(inc, cold)
    s = inc.exec_stats
    assert s.segments_rescanned == s.chunks_total > 4
    assert s.segments_reused == 0
    # warm, unchanged: everything served from frozen state, zero passes
    warm = pipe(store=tmp_path / "st").run(data.decode())
    assert_bit_identical(warm, cold)
    s = warm.exec_stats
    assert s.segments_rescanned == 0
    assert s.bytes_rescanned == 0
    assert s.segments_reused == s.chunks_total
    assert warm.passes == 0
    assert s.mode == "incremental"


def test_append_rescans_only_the_tail(tmp_path):
    data = corpus()
    store = tmp_path / "st"
    pipe(store=store).run(data.decode())
    appended = data + bsbm_ntriples(5, seed=77).encode()
    inc = pipe(store=store).run(appended.decode())
    cold = pipe().run(appended.decode())
    assert_bit_identical(inc, cold)
    s = inc.exec_stats
    assert s.segments_rescanned <= 2
    assert s.segments_reused >= s.chunks_total - 2
    assert s.bytes_rescanned < 0.2 * s.bytes_total


def _random_edit(rng, data: bytes) -> bytes:
    lines = data.split(b"\n")
    if lines and lines[-1] == b"":
        lines.pop()
    op = rng.integers(0, 3)
    if op == 0 or len(lines) < 10:       # append a few fresh triples
        extra = bsbm_ntriples(int(rng.integers(1, 6)),
                              seed=int(rng.integers(1 << 30)))
        return data + extra.encode()
    if op == 1:                           # delete a random region
        i = int(rng.integers(0, len(lines) - 5))
        j = i + int(rng.integers(1, min(len(lines) - i, 200)))
        del lines[i:j]
    else:                                 # in-place mutation
        i = int(rng.integers(0, len(lines)))
        lines[i] = (b'<http://mut.example/s%d> <http://mut.example/p> '
                    b'"%d" .' % (int(rng.integers(1000)),
                                 int(rng.integers(1000))))
    return b"\n".join(lines) + b"\n"


@pytest.mark.parametrize("backend", ["jnp", "pallas", "fused_scan"])
def test_randomized_edit_sequence_bit_identical(tmp_path, backend):
    """The acceptance criterion: incremental == cold (registers included)
    after every step of a random append/delete/mutate sequence, for every
    backend.  jnp gets a longer program; the interpret-mode kernel
    backends get a shorter one to keep the suite fast — the store path is
    backend-independent above the evaluator, so the cross-backend signal
    is that frozen states and rescans merge identically everywhere."""
    rng = np.random.default_rng(0xC0FFEE if backend == "jnp" else 7)
    steps = 5 if backend == "jnp" else 2
    data = corpus(220, seed=3)
    store = tmp_path / "st"
    p_inc, p_cold = pipe(backend, store=store), pipe(backend)
    for step in range(steps):
        inc = p_inc.run(data.decode())
        cold = p_cold.run(data.decode())
        assert_bit_identical(inc, cold)
        data = _random_edit(rng, data)
    # a reuse actually happened somewhere (the sequence isn't all-cold)
    hist = SegmentStore(os.fspath(store), engine_signature(
        p_inc.evaluator(), BASE)).history()
    assert len(hist) == steps
    assert any(h.get("segments_reused", 0) > 0 for h in hist[1:])


def test_store_written_by_one_backend_reused_by_another(tmp_path):
    """The engine signature excludes the backend: all backends are
    bit-identical, so frozen states are interchangeable."""
    data = corpus(250, seed=9)
    store = tmp_path / "st"
    pipe("jnp", store=store).run(data.decode())
    inc = pipe("fused_scan", store=store).run(data.decode())
    assert inc.exec_stats.segments_rescanned == 0
    assert_bit_identical(inc, pipe("jnp").run(data.decode()))


def test_duplicate_segments_merge_per_occurrence(tmp_path):
    """The same bytes appearing twice is ONE state file but TWO merge
    contributions: counts are additive per occurrence, registers
    idempotent."""
    block = corpus(120, seed=21)
    assert len(split_segments(block, SEG)) >= 2
    doubled = block + block
    inc = pipe(store=tmp_path / "st").run(doubled.decode())
    cold = pipe().run(doubled.decode())
    assert_bit_identical(inc, cold)
    warm = pipe(store=tmp_path / "st").run(doubled.decode())
    assert warm.exec_stats.segments_rescanned == 0
    assert_bit_identical(warm, cold)


def test_explicit_chunk_stream_as_segments(tmp_path):
    """An iterable of line-aligned text chunks is an explicit
    segmentation: each chunk is one content-addressed segment."""
    blocks = [bsbm_ntriples(60, seed=s) for s in (1, 2, 3)]
    whole = "".join(blocks)
    cold = pipe().run(whole)
    inc = pipe(store=tmp_path / "st").run(iter(blocks))
    assert_bit_identical(inc, cold)
    assert inc.exec_stats.chunks_total == 3
    # replacing one chunk rescans exactly that chunk
    blocks2 = [blocks[0], bsbm_ntriples(60, seed=8), blocks[2]]
    inc2 = pipe(store=tmp_path / "st").run(iter(blocks2))
    cold2 = pipe().run("".join(blocks2))
    assert_bit_identical(inc2, cold2)
    assert inc2.exec_stats.segments_rescanned >= 1
    assert inc2.exec_stats.segments_reused >= 1


def test_pipelined_incremental(tmp_path):
    data = corpus(250, seed=4)
    store = tmp_path / "st"
    p = pipe(store=store).pipelined(1)
    inc = p.run(data.decode())
    cold = pipe().run(data.decode())
    assert_bit_identical(inc, cold)
    assert inc.exec_stats.mode == "incremental+pipelined"
    warm = p.run(data.decode())
    assert warm.exec_stats.segments_rescanned == 0
    assert_bit_identical(warm, cold)


# --- robustness ---------------------------------------------------------------

def _state_files(store_dir):
    seg_dir = os.path.join(store_dir, "segments")
    return sorted(os.path.join(seg_dir, n) for n in os.listdir(seg_dir))


def test_truncated_state_file_rescans_that_segment_only(tmp_path):
    data = corpus()
    store = os.fspath(tmp_path / "st")
    cold = pipe().run(data.decode())
    pipe(store=store).run(data.decode())
    victim = _state_files(store)[2]
    with open(victim, "rb") as f:
        blob = f.read()
    with open(victim, "wb") as f:
        f.write(blob[:len(blob) // 2])   # torn write
    inc = pipe(store=store).run(data.decode())
    assert_bit_identical(inc, cold)
    s = inc.exec_stats
    assert s.segments_rescanned == 1     # only the corrupt one
    assert s.segments_reused == s.chunks_total - 1
    # the rescan re-froze it: next run is fully warm again
    warm = pipe(store=store).run(data.decode())
    assert warm.exec_stats.segments_rescanned == 0


def test_corrupted_state_bytes_detected_by_digest(tmp_path):
    """Same-length bit corruption: only the content digest can catch it."""
    data = corpus(200, seed=5)
    store = os.fspath(tmp_path / "st")
    cold = pipe().run(data.decode())
    pipe(store=store).run(data.decode())
    victim = _state_files(store)[0]
    with open(victim, "r+b") as f:
        f.seek(100)
        b = f.read(1)
        f.seek(100)
        f.write(bytes([b[0] ^ 0xFF]))
    inc = pipe(store=store).run(data.decode())
    assert_bit_identical(inc, cold)
    assert inc.exec_stats.segments_rescanned == 1


def test_missing_state_file_rescans_that_segment_only(tmp_path):
    data = corpus(200, seed=6)
    store = os.fspath(tmp_path / "st")
    cold = pipe().run(data.decode())
    pipe(store=store).run(data.decode())
    os.remove(_state_files(store)[1])
    inc = pipe(store=store).run(data.decode())
    assert_bit_identical(inc, cold)
    assert inc.exec_stats.segments_rescanned == 1


def test_corrupt_manifest_recovers_from_self_verifying_states(tmp_path):
    """A corrupt manifest discards the committed descriptors, but state
    files are self-verifying (embedded payload + signature digests), so
    intact states are adopted as orphans instead of rescanned — and the
    next commit rewrites a valid manifest."""
    data = corpus(200, seed=7)
    store = os.fspath(tmp_path / "st")
    cold = pipe().run(data.decode())
    pipe(store=store).run(data.decode())
    manifest = os.path.join(store, "manifest.json")
    with open(manifest) as f:
        doc = json.load(f)
    doc["payload"]["segments"][0]["n_triples"] += 1  # digest now wrong
    with open(manifest, "w") as f:
        json.dump(doc, f)
    inc = pipe(store=store).run(data.decode())
    assert_bit_identical(inc, cold)
    assert inc.exec_stats.segments_rescanned == 0    # orphans adopted
    warm = pipe(store=store).run(data.decode())
    assert warm.exec_stats.segments_rescanned == 0
    assert_bit_identical(warm, cold)


def test_crash_between_freeze_and_commit_resumes(tmp_path):
    """States freeze as segments merge but the manifest commits at the
    end — the in-run crash-recovery story for incremental scans: a rerun
    adopts every already-frozen segment instead of rescanning from zero
    (`checkpoint/` in-run resume is not wired into incremental mode; this
    is its equivalent)."""
    data = corpus(200, seed=8)
    store = os.fspath(tmp_path / "st")
    cold = pipe().run(data.decode())
    pipe(store=store).run(data.decode())
    os.remove(os.path.join(store, "manifest.json"))  # crash before commit
    inc = pipe(store=store).run(data.decode())
    assert_bit_identical(inc, cold)
    assert inc.exec_stats.segments_rescanned == 0
    # truncated manifest (torn write) behaves the same
    manifest = os.path.join(store, "manifest.json")
    with open(manifest, "r+") as f:
        f.truncate(os.path.getsize(manifest) // 2)
    inc2 = pipe(store=store).run(data.decode())
    assert_bit_identical(inc2, cold)
    assert inc2.exec_stats.segments_rescanned == 0


def test_orphan_with_wrong_signature_rejected(tmp_path):
    """Orphan adoption must not outflank the engine-signature check: a
    state frozen under a different hll_p has differently-shaped register
    banks and must be rescanned, not merged."""
    data = corpus(150, seed=19)
    store = os.fspath(tmp_path / "st")
    pipe(store=store).run(data.decode())
    os.remove(os.path.join(store, "manifest.json"))  # all states orphaned
    other = qa.pipeline().metrics(ALL_METRICS).base(*BASE).hll(10) \
        .incremental(store, segment_bytes=SEG)
    inc = other.run(data.decode())
    assert inc.exec_stats.segments_reused == 0
    cold = qa.pipeline().metrics(ALL_METRICS).base(*BASE).hll(10) \
        .run(data.decode())
    assert_bit_identical(inc, cold)


def test_different_engine_signature_invalidates_store(tmp_path):
    """States frozen under other metrics / hll_p describe different
    counter layouts or register banks — they must not be reused, and the
    store must not crash on the signature flip."""
    data = corpus(150, seed=10)
    store = tmp_path / "st"
    pipe(store=store).run(data.decode())
    other = qa.pipeline().metrics("paper").base(*BASE).hll(10) \
        .incremental(store, segment_bytes=SEG)
    inc = other.run(data.decode())
    assert inc.exec_stats.segments_reused == 0
    cold = qa.pipeline().metrics("paper").base(*BASE).hll(10) \
        .run(data.decode())
    assert_bit_identical(inc, cold)
    # the original engine now misses ITS manifest in turn (replaced)
    back = pipe(store=store).run(data.decode())
    assert back.exec_stats.segments_reused == 0


def test_early_delete_reuses_all_downstream_segments(tmp_path):
    """THE plane-layout-v2 payoff: deleting an early region renumbers
    every term first seen after it, but frozen sketches hash term
    *content*, so the unaffected downstream segments are all reused —
    only the segment(s) framing the edit rescan — and the result is
    still bit-identical to cold (pre-v2 this renumbering cascade forced
    a rescan of every downstream segment)."""
    data = corpus(400, seed=12)
    store = tmp_path / "st"
    first = pipe(store=store).run(data.decode())
    n_segs = first.exec_stats.chunks_total
    assert n_segs >= 6
    cut = data.find(b"\n", 2000) + 1
    cut2 = data.find(b"\n", 9000) + 1
    edited = data[:cut] + data[cut2:]     # delete inside the FIRST segment
    inc = pipe(store=store).run(edited.decode())
    cold = pipe().run(edited.decode())
    assert_bit_identical(inc, cold)
    s = inc.exec_stats
    assert s.segments_rescanned <= 2      # only the edit-framing segment(s)
    assert s.segments_reused >= s.chunks_total - 2
    assert s.bytes_rescanned < 0.25 * s.bytes_total


def test_mutation_is_edit_local(tmp_path):
    """An in-place mutation mid-corpus rescans only the segments framing
    the rewritten region; everything downstream is reused from frozen
    state despite the id renumbering it causes."""
    data = corpus(400, seed=18)
    store = tmp_path / "st"
    pipe(store=store).run(data.decode())
    a = data.find(b"\n", len(data) // 3) + 1
    b = data.find(b"\n", a + len(data) // 20) + 1    # ~5% region
    replacement = bsbm_ntriples(20, seed=999).encode()
    edited = data[:a] + replacement + data[b:]
    inc = pipe(store=store).run(edited.decode())
    cold = pipe().run(edited.decode())
    assert_bit_identical(inc, cold)
    s = inc.exec_stats
    assert s.segments_reused > s.segments_rescanned
    # pre-v2 the renumbering cascade rescanned the edit plus EVERYTHING
    # downstream of the 1/3 mark (≥ ~70% of bytes); edit-local reuse must
    # stay clearly under that even with CDC boundary slop around the edit
    assert s.bytes_rescanned < 0.5 * s.bytes_total


def test_user_metric_on_id_planes_keeps_replay_gate(tmp_path):
    """Unconditional reuse is only sound for content-determined plans.
    A user-registered metric may still sketch raw term-id planes; for
    such plans the incremental planner must keep the PR 4 replayed-id
    equality gate (rescan renumbered downstream segments), preserving
    bit-exactness at the old reuse level instead of silently serving
    stale registers."""
    from repro.core.metrics import Metric, register, unregister, \
        valid_triple
    from repro.rdf.triple_tensor import COL_S
    from repro.store.runner import plans_renumbering_invariant
    register(Metric(
        name="ID_SKETCH", dimension="custom",
        description="distinct subjects via the raw id plane",
        counters=(("total", valid_triple()),),
        finalize=lambda c: float(c.get("sketch:s_id", 0)),
        sketches=(("s_id", (COL_S,)),)))
    try:
        names = tuple(ALL_METRICS) + ("ID_SKETCH",)
        p_inc = (qa.pipeline().metrics(names).base(*BASE)
                 .incremental(tmp_path / "st", segment_bytes=SEG))
        p_cold = qa.pipeline().metrics(names).base(*BASE)
        assert not plans_renumbering_invariant(p_inc.evaluator())
        assert plans_renumbering_invariant(pipe().evaluator())

        data = corpus(300, seed=40)
        p_inc.run(data.decode())
        cut = data.find(b"\n", 1500) + 1
        cut2 = data.find(b"\n", 6000) + 1
        edited = data[:cut] + data[cut2:]   # early delete renumbers ids
        inc = p_inc.run(edited.decode())
        cold = p_cold.run(edited.decode())
        assert_bit_identical(inc, cold)
        # the gate re-engaged: the renumbering cascade rescanned beyond
        # the edit-framing segments (content-determined plans stay ≤ 2)
        assert inc.exec_stats.segments_rescanned > 2
    finally:
        unregister("ID_SKETCH")


def test_pre_rev_store_signature_mismatch_self_heals(tmp_path):
    """A store written under the previous plane layout (v1: sketches
    hashed term-id planes; its engine signature carries no/other
    ``plane_layout``) must be rejected wholesale — cold rescan, no shape
    collisions, and the store is rebuilt under the new signature."""
    data = corpus(200, seed=15)
    store = os.fspath(tmp_path / "st")
    cold = pipe().run(data.decode())
    pipe(store=store).run(data.decode())

    # forge the pre-rev layout: rewrite manifest + states under the OLD
    # signature (plane_layout stripped), exactly what a v1 store holds
    sig_new = engine_signature(pipe(store=store).evaluator(), BASE)
    assert sig_new["plane_layout"] >= 2
    sig_old = {k: v for k, v in sig_new.items() if k != "plane_layout"}
    old = SegmentStore(store, sig_old)
    cur = SegmentStore(store, sig_new)
    descrs = cur.known_segments
    assert descrs
    for d in descrs:
        st = cur.load_state(d["fp"])
        old.put_state(st)               # re-freeze under the old signature
    old.commit([{k: s[k] for k in ("fp", "n_bytes", "n_triples")}
                for s in descrs])

    # the current engine must not reuse ANY of it — and must not crash
    inc = pipe(store=store).run(data.decode())
    assert inc.exec_stats.segments_reused == 0
    assert inc.exec_stats.segments_rescanned == inc.exec_stats.chunks_total
    assert_bit_identical(inc, cold)
    warm = pipe(store=store).run(data.decode())   # rebuilt: warm again
    assert warm.exec_stats.segments_rescanned == 0
    assert_bit_identical(warm, cold)


# --- concurrency --------------------------------------------------------------

def _mini_state(fp: str, seed: int):
    from repro.store import SegmentState
    rng = np.random.default_rng(seed)
    return SegmentState(
        fingerprint=fp, n_bytes=64, n_triples=2,
        counts=[rng.integers(0, 9, 3).astype(np.int64)],
        regs={"spo": rng.integers(0, 5, 16).astype(np.int32)},
        keys=[b"<http://x/a>", b"<http://x/b>"],
        flags=np.array([9, 9], np.int32),
        lengths=np.array([10, 10], np.int64),
        datatypes=np.array([0, 0], np.int32),
        ids=np.array([0, 1], np.int64))


def test_interleaved_commits_lock_cas_and_gc_grace(tmp_path):
    """Two runners against one store dir, interleaved through the
    classic race window (both load, one commits while the other still
    holds pending work).  The loser's pending state must survive the
    winner's GC (grace), the loser's commit must CAS past the winner's
    version AND be able to reference a segment only the winner froze
    (merged digests), and the final manifest must verify."""
    sig = {"format": 1, "plane_layout": 2, "test": True}
    d = os.fspath(tmp_path / "st")
    a_store = SegmentStore(d, sig)
    b_store = SegmentStore(d, sig)          # both see version 0
    assert a_store.version == b_store.version == 0

    a_store.put_state(_mini_state("aaaa", 1))
    b_store.put_state(_mini_state("bbbb", 2))
    b_store.commit([{"fp": "bbbb", "n_bytes": 64, "n_triples": 2}])
    assert b_store.version == 1
    # A's pending (uncommitted) state survived B's GC — grace period
    assert os.path.exists(os.path.join(d, "segments", "aaaa.seg"))

    # A commits its own segment AND one only B put+committed: the CAS
    # reload under the lock merges B's digests, so this must not raise
    a_store.commit([{"fp": "aaaa", "n_bytes": 64, "n_triples": 2},
                    {"fp": "bbbb", "n_bytes": 64, "n_triples": 2}])
    assert a_store.version == 2

    fresh = SegmentStore(d, sig)
    assert fresh.version == 2
    assert [s["fp"] for s in fresh.known_segments] == ["aaaa", "bbbb"]
    for s in fresh.known_segments:          # every referenced file exists
        assert fresh.load_state(s["fp"]) is not None


def test_two_interleaved_monitors_one_store(tmp_path):
    """End-to-end: two concurrent incremental runners (the --watch
    scenario) against one store dir must both complete, leave a valid
    manifest, and never corrupt results — the final warm run is
    bit-identical to cold."""
    import threading
    data = corpus(150, seed=31)
    edited = data + bsbm_ntriples(5, seed=32).encode()
    store = os.fspath(tmp_path / "st")
    gate = threading.Barrier(2, timeout=30)
    errors = []

    def monitor(ds: bytes):
        try:
            gate.wait()
            for _ in range(2):
                pipe(store=store).run(ds.decode())
        except Exception as e:               # pragma: no cover - fail loudly
            errors.append(e)

    threads = [threading.Thread(target=monitor, args=(ds,))
               for ds in (data, edited)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors
    final = pipe(store=store).run(edited.decode())
    assert_bit_identical(final, pipe().run(edited.decode()))
    # the store is healthy and committed by somebody at version >= 4
    st = SegmentStore(store, engine_signature(pipe(store=store).evaluator(),
                                              BASE))
    assert st.version >= 4
    assert st.known_segments


# --- API surface --------------------------------------------------------------

def test_tensor_input_rejected_for_incremental(tmp_path):
    from repro.rdf import synth_encoded
    with pytest.raises(TypeError, match="segment store"):
        pipe(store=tmp_path / "st").run(synth_encoded(100, seed=0))


def test_assess_store_alias_and_execution_config(tmp_path):
    data = bsbm_ntriples(80, seed=2)
    res = qa.assess(data, metrics="paper", base=BASE,
                    store=os.fspath(tmp_path / "st"), segment_bytes=SEG)
    assert res.exec_stats.bytes_total > 0
    res2 = qa.assess(data, metrics="paper", base=BASE,
                     store=os.fspath(tmp_path / "st"), segment_bytes=SEG)
    assert res2.exec_stats.segments_rescanned == 0
    assert res2.values == res.values
    with pytest.raises(ValueError, match="segment_bytes"):
        qa.ExecutionConfig(segment_bytes=-1)


def test_history_written_per_run(tmp_path):
    data = corpus(100, seed=13)
    store = tmp_path / "st"
    p = pipe(store=store)
    p.run(data.decode())
    p.run((data + bsbm_ntriples(4, seed=44).encode()).decode())
    from repro.core import report
    hist = report.load_history(store / "history.jsonl")
    assert len(hist) == 2
    assert hist[1]["segments_reused"] >= 1
    trend = report.to_dqv_history(hist)
    assert trend["snapshots"] == 2 and trend["metrics"]


# --- lazy footprint replay ----------------------------------------------------

def test_warm_run_replays_no_footprints(tmp_path):
    """Fully warm no-change runs skip dictionary replay entirely (the
    frozen planes already carry everything the merge needs) while
    staying bit-identical to a cold assessment."""
    data = corpus(300)
    store = tmp_path / "st"
    (tmp_path / "d.nt").write_bytes(data)
    path = os.fspath(tmp_path / "d.nt")

    cold = pipe(store=store).run(path)
    assert cold.exec_stats.segments_rescanned > 4
    assert cold.exec_stats.footprints_replayed == 0

    warm = pipe(store=store).run(path)
    assert warm.exec_stats.segments_rescanned == 0
    assert warm.exec_stats.footprints_replayed == 0
    assert_bit_identical(warm, cold)


def test_edit_replays_only_preceding_footprints(tmp_path):
    """A rescan needs cold-identical dictionary ids, so reused segments
    BEFORE the first rescanned one replay their footprints — but
    segments after the last rescan never do."""
    data = corpus(300)
    store = tmp_path / "st"
    path = tmp_path / "d.nt"
    path.write_bytes(data)
    pipe(store=store).run(os.fspath(path))

    # mutate one line near the start: nearly every reused segment sits
    # AFTER the edit, so almost nothing replays
    a = data.find(b"\n", len(data) // 20) + 1
    b = data.find(b"\n", a) + 1
    edited = data[:a] + b"<http://x/s> <http://x/p> <http://x/o> .\n" \
        + data[b:]
    path.write_bytes(edited)
    res = pipe(store=store).run(os.fspath(path))
    s = res.exec_stats
    assert s.segments_rescanned >= 1
    assert s.footprints_replayed <= 1       # at most the first segment
    assert s.footprints_replayed < s.segments_reused
    assert_bit_identical(res, pipe().run(os.fspath(path)))


# --- compaction ---------------------------------------------------------------

def test_compact_removes_stale_segments_and_keeps_reuse(tmp_path):
    """Edits strand superseded ``.seg`` files (the per-commit GC spares
    anything younger than its grace window); ``compact()`` reclaims them
    immediately, and the compacted store still reuses everything."""
    data = corpus(300)
    store = tmp_path / "st"
    path = tmp_path / "d.nt"
    path.write_bytes(data)
    pipe(store=store).run(os.fspath(path))

    # rewrite a mid-file region twice: two generations of stale segments
    for seed in (71, 72):
        a = data.find(b"\n", len(data) // 2) + 1
        b = data.find(b"\n", a + len(data) // 10) + 1
        data = data[:a] + bsbm_ntriples(30, seed=seed).encode() + data[b:]
        path.write_bytes(data)
        pipe(store=store).run(os.fspath(path))

    seg_dir = store / "segments"
    st = SegmentStore(os.fspath(store), signature={})
    live = {s["fp"] for s in st._disk_manifest_raw()["segments"]}
    on_disk = {n[:-4] for n in os.listdir(seg_dir) if n.endswith(".seg")}
    assert on_disk > live               # stale generations survived GC

    stats = SegmentStore.compact_dir(store)
    assert stats["segments_removed"] == len(on_disk - live)
    assert stats["bytes_reclaimed"] > 0
    now_on_disk = {n[:-4] for n in os.listdir(seg_dir)
                   if n.endswith(".seg")}
    assert now_on_disk == live

    warm = pipe(store=store).run(os.fspath(path))
    assert warm.exec_stats.segments_rescanned == 0
    assert_bit_identical(warm, pipe().run(os.fspath(path)))

    # a directory that never held a store compacts to all-zero stats
    empty = SegmentStore.compact_dir(tmp_path / "nowhere")
    assert empty == {"segments_kept": 0, "segments_removed": 0,
                     "bytes_reclaimed": 0, "history_dropped": 0}


# --- history retention --------------------------------------------------------

def test_max_history_keeps_newest_snapshots(tmp_path):
    data = corpus(80, seed=3)
    store = tmp_path / "st"
    path = tmp_path / "d.nt"
    p = qa.pipeline().metrics("paper").base(*BASE).incremental(
        os.fspath(store), segment_bytes=SEG, max_history=3)
    for i in range(5):
        path.write_bytes(data + bsbm_ntriples(i + 1, seed=i).encode())
        p.run(os.fspath(path))
    from repro.core import report
    hist = report.load_history(store / "history.jsonl")
    assert len(hist) == 3
    # newest retained: triple counts strictly grew run over run
    counts = [h["nTriples"] for h in hist]
    assert counts == sorted(counts) and counts[-1] > counts[0]

    # compact() applies the same retention on demand
    stats = SegmentStore.compact_dir(store, max_history=1)
    assert stats["history_dropped"] == 2
    assert len(report.load_history(store / "history.jsonl")) == 1
    with pytest.raises(ValueError, match="max_history"):
        qa.ExecutionConfig(max_history=-1)


# --- integrity verification (fsck) --------------------------------------------

def _seg_files(store):
    segs = os.path.join(os.fspath(store), "segments")
    return sorted(os.path.join(segs, f) for f in os.listdir(segs)
                  if f.endswith(".seg"))


def test_verify_clean_store(tmp_path):
    store = tmp_path / "st"
    pipe(store=store).run(corpus(120, seed=5))
    rep = SegmentStore.verify_dir(store)
    assert rep["exists"] and rep["clean"]
    assert rep["segments_checked"] == rep["segments_ok"] > 1
    assert rep["missing"] == [] and rep["corrupt"] == []


def test_verify_detects_bitrot_and_missing_segments(tmp_path):
    store = tmp_path / "st"
    pipe(store=store).run(corpus(200, seed=6))
    files = _seg_files(store)
    assert len(files) >= 3
    # flip one byte deep in a payload (past the header line)
    with open(files[0], "r+b") as f:
        f.seek(-1, os.SEEK_END)
        last = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([last[0] ^ 0xFF]))
    os.unlink(files[1])
    rep = SegmentStore.verify_dir(store)
    assert not rep["clean"]
    assert len(rep["corrupt"]) == 1 and len(rep["missing"]) == 1
    assert "digest" in rep["corrupt"][0]["issue"]
    # damage is detected, never silently repaired: a fresh incremental
    # run self-heals by rescanning, and verify comes back clean
    res = pipe(store=store).run(corpus(200, seed=6))
    assert res.exec_stats.bytes_rescanned > 0
    assert SegmentStore.verify_dir(store)["clean"]


def test_verify_dir_on_non_store_is_vacuously_clean(tmp_path):
    rep = SegmentStore.verify_dir(tmp_path / "nowhere")
    assert rep == {"exists": False, "clean": True, "segments_checked": 0,
                   "segments_ok": 0, "missing": [], "corrupt": [],
                   "orphans": 0}
    # crucially, probing never creates store directories
    assert not os.path.exists(tmp_path / "nowhere")


def test_verify_counts_orphans_without_failing(tmp_path):
    store = tmp_path / "st"
    pipe(store=store).run(corpus(120, seed=7))
    orphan = os.path.join(os.fspath(store), "segments", "feed" * 8 + ".seg")
    with open(orphan, "wb") as f:
        f.write(b"stray bytes not in any manifest")
    rep = SegmentStore.verify_dir(store)
    assert rep["clean"] and rep["orphans"] == 1

"""Crash-safety and graceful-degradation of ``repro.serve``: the
write-ahead job journal (replay after ``kill -9`` with bit-identical
results), retry/backoff on transient failures, the per-job watchdog,
per-dataset circuit breakers (503 quarantine vs 429 backpressure),
``DELETE /datasets/<name>`` lifecycle GC, finished-job retention, and
bounded webhook retries — all driven by ``ServiceFaultInjector``."""
import http.server
import json
import os
import signal
import subprocess
import sys
import textwrap
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import qa
from repro.rdf import bsbm_ntriples
from repro.serve import (DatasetQuarantined, JobJournal, JobQueue,
                         QAServer, ServerConfig, ServiceFaultInjector,
                         TransientJobError, post_webhook)

BASE = ("http://bsbm.example.org/",)
SEG = 4096
SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def req(port, method, path, body=None):
    """(status, parsed-or-raw body); 4xx/5xx don't raise."""
    r = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", data=body, method=method)
    try:
        with urllib.request.urlopen(r, timeout=30) as resp:
            raw, status = resp.read(), resp.status
            ctype = resp.headers.get("Content-Type", "")
            headers = dict(resp.headers)
    except urllib.error.HTTPError as e:
        raw, status = e.read(), e.code
        ctype = e.headers.get("Content-Type", "")
        headers = dict(e.headers)
    if ctype.startswith("application/json"):
        return status, json.loads(raw), headers
    return status, raw, headers


def wait_job(port, name, job_id, timeout=120.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        st, job, _ = req(port, "GET", f"/datasets/{name}/jobs/{job_id}")
        assert st == 200, job
        if job["state"] in ("done", "failed"):
            return job
        time.sleep(0.02)
    raise AssertionError(f"job {job_id} still {job['state']}")


def upload(port, name, text):
    st, doc, _ = req(port, "PUT", f"/datasets/{name}/data",
                     body=text.encode())
    assert st == 202, doc
    return doc["job"]["id"]


def make_server(tmp_path, faults=None, **cfg):
    defaults = dict(store_root=os.fspath(tmp_path / "root"),
                    metrics="paper", base=BASE, workers=2,
                    segment_bytes=SEG, watch=False, retry_base=0.05)
    defaults.update(cfg)
    return QAServer(ServerConfig(**defaults), port=0,
                    faults=faults).start()


# -- journal unit behaviour ----------------------------------------------------

def test_journal_replay_torn_tail_and_tombstone(tmp_path):
    path = os.fspath(tmp_path / "jobs.jsonl")
    j = JobJournal(path)
    j.append("enqueue", job=1, dataset="a", trigger="upload", path="/p1")
    j.append("enqueue", job=2, dataset="a", trigger="manual", path="/p2")
    j.append("enqueue", job=3, dataset="b", trigger="watch", path="/p3")
    j.append("start", job=1, attempt=1)
    j.append("finish", job=1, state="done", error=None)
    j.append("start", job=2, attempt=1)
    j.append("retry", job=2, attempt=1, error="x", next_at=0.0)
    j.close()
    # torn tail of a crashed append: must be skipped, not fatal
    with open(path, "a") as f:
        f.write('{"ev": "fin')
    unfinished, max_id = JobJournal.replay(path)
    assert max_id == 3
    assert [(r["id"], r["dataset"], r["trigger"], r["path"])
            for r in unfinished] == [(2, "a", "manual", "/p2"),
                                     (3, "b", "watch", "/p3")]
    # a tombstone voids the dataset's unfinished jobs up to that point
    j2 = JobJournal(path)
    j2.append("tombstone", dataset="a")
    j2.close()
    unfinished, max_id = JobJournal.replay(path)
    assert [r["id"] for r in unfinished] == [3] and max_id == 3
    # compaction: reset() atomically replaces the contents
    j3 = JobJournal(path)
    j3.reset([JobJournal.enqueue_record(3, "b", "watch", "/p3",
                                        requeued=True)])
    j3.close()
    recs = JobJournal.load(path)
    assert len(recs) == 1 and recs[0]["job"] == 3 and recs[0]["requeued"]


def test_journal_write_through_and_compaction_on_restart(tmp_path):
    """Every accepted job hits the journal before submit returns; a
    restarted daemon compacts the journal down to the replayed jobs."""
    srv = make_server(tmp_path)
    try:
        data = bsbm_ntriples(30, seed=1)
        jid = upload(srv.port, "wj", data)
        recs = JobJournal.load(srv.journal.path)
        assert any(r["ev"] == "enqueue" and r["job"] == jid for r in recs)
        assert wait_job(srv.port, "wj", jid)["state"] == "done"
        recs = JobJournal.load(srv.journal.path)
        assert any(r["ev"] == "finish" and r["job"] == jid
                   and r["state"] == "done" for r in recs)
        root = srv.registry.root
    finally:
        srv.close()
    srv2 = QAServer(ServerConfig(store_root=root, metrics="paper",
                                 base=BASE, segment_bytes=SEG,
                                 watch=False), port=0).start()
    try:
        # nothing unfinished -> compacted to empty; ids keep counting up
        assert JobJournal.load(srv2.journal.path) == []
        jid2 = upload(srv2.port, "wj", data)
        assert jid2 > jid
    finally:
        srv2.close()


# -- retry / backoff / attempt surfacing ---------------------------------------

def test_transient_failure_retries_to_success(tmp_path):
    faults = ServiceFaultInjector(fail_jobs={"r1": 2})
    srv = make_server(tmp_path, faults=faults, max_attempts=4)
    try:
        data = bsbm_ntriples(40, seed=2)
        job = wait_job(srv.port, "r1", upload(srv.port, "r1", data))
        assert job["state"] == "done", job["error"]
        assert job["attempts"] == 3            # 2 injected failures + 1
        assert job["max_attempts"] == 4
        # values still exactly the cold run's despite the retries
        cold = qa.assess(data, metrics="paper", base=BASE)
        assert job["values"] == {k: float(v)
                                 for k, v in sorted(cold.values.items())}
        st, prom, _ = req(srv.port, "GET", "/metrics")
        assert 'repro_job_retries_total{dataset="r1"} 2' in prom.decode()
    finally:
        srv.close()


def test_permanent_failure_never_retries(tmp_path):
    faults = ServiceFaultInjector(permanent_fail={"p1"})
    srv = make_server(tmp_path, faults=faults, max_attempts=4,
                      breaker_threshold=0)
    try:
        job = wait_job(srv.port, "p1",
                       upload(srv.port, "p1", bsbm_ntriples(20, seed=3)))
        assert job["state"] == "failed"
        assert job["attempts"] == 1            # permanent: no retries
        assert "injected permanent failure" in job["error"]
    finally:
        srv.close()


def test_watchdog_expires_hung_job_and_frees_worker(tmp_path):
    faults = ServiceFaultInjector(slow_jobs={"hung": 10.0})
    srv = make_server(tmp_path, faults=faults, workers=1,
                      max_attempts=1, job_timeout=0.4)
    try:
        t0 = time.time()
        job = wait_job(srv.port, "hung",
                       upload(srv.port, "hung", bsbm_ntriples(20, seed=4)))
        assert job["state"] == "failed"
        assert "watchdog" in job["error"]
        assert time.time() - t0 < 8.0          # expired, not slept out
        # the single worker is free again: a healthy dataset completes
        # while the abandoned thread is still sleeping
        ok = wait_job(srv.port, "ok",
                      upload(srv.port, "ok", bsbm_ntriples(20, seed=5)))
        assert ok["state"] == "done", ok["error"]
        st, prom, _ = req(srv.port, "GET", "/metrics")
        assert 'repro_job_timeouts_total{dataset="hung"} 1' \
            in prom.decode()
    finally:
        srv.close()


# -- circuit breaker -----------------------------------------------------------

def test_breaker_quarantines_poison_dataset_then_probes(tmp_path):
    faults = ServiceFaultInjector(permanent_fail={"bad"})
    srv = make_server(tmp_path, faults=faults, max_attempts=1,
                      breaker_threshold=2, breaker_cooldown=1.0)
    try:
        data = bsbm_ntriples(30, seed=6)
        for _ in range(2):
            job = wait_job(srv.port, "bad", upload(srv.port, "bad", data))
            assert job["state"] == "failed"
        # breaker open: submits answer 503 + Retry-After (not 429)
        st, doc, headers = req(srv.port, "POST", "/datasets/bad/assess")
        assert st == 503 and "quarantined" in doc["error"]
        assert int(headers["Retry-After"]) >= 1
        st, info, _ = req(srv.port, "GET", "/datasets/bad")
        assert info["breaker"]["state"] == "open"
        # ...while a healthy tenant keeps running
        ok = wait_job(srv.port, "good",
                      upload(srv.port, "good", bsbm_ntriples(30, seed=7)))
        assert ok["state"] == "done", ok["error"]
        st, prom, _ = req(srv.port, "GET", "/metrics")
        text = prom.decode()
        assert 'repro_breaker_open_total{dataset="bad"} 1' in text
        assert 'repro_jobs_quarantined_total{dataset="bad"} 1' in text

        # the poison payload gets fixed; after the cool-down one probe
        # is admitted, succeeds, and closes the breaker
        faults.permanent_fail.clear()
        time.sleep(1.1)
        st, doc, _ = req(srv.port, "POST", "/datasets/bad/assess")
        assert st == 202, doc
        probe = wait_job(srv.port, "bad", doc["job"]["id"])
        assert probe["state"] == "done", probe["error"]
        st, info, _ = req(srv.port, "GET", "/datasets/bad")
        assert info["breaker"]["state"] == "closed"
        st, doc, _ = req(srv.port, "POST", "/datasets/bad/assess")
        assert st == 202                      # fully back in service
        wait_job(srv.port, "bad", doc["job"]["id"])
    finally:
        srv.close()


def test_breaker_reopens_on_failed_probe():
    """Queue-level: a probe that fails re-opens the breaker with a
    doubled cool-down; only one probe is admitted per cool-down."""
    boom = RuntimeError("still broken")

    def body(job):
        raise boom
    q = JobQueue(workers=1, fn=body, breaker_threshold=1,
                 breaker_cooldown=0.2)
    try:
        j = q.submit("ds")
        deadline = time.time() + 10
        while q.get(j.id)["state"] != "failed":
            assert time.time() < deadline
            time.sleep(0.01)
        with pytest.raises(DatasetQuarantined):
            q.submit("ds")
        time.sleep(0.25)
        probe = q.submit("ds")                 # half-open: probe admitted
        with pytest.raises(DatasetQuarantined):
            q.submit("ds")                     # but only one at a time
        while q.get(probe.id)["state"] != "failed":
            assert time.time() < deadline
            time.sleep(0.01)
        with pytest.raises(DatasetQuarantined) as exc:
            q.submit("ds")                     # re-opened, cool-down x2
        assert exc.value.retry_after > 0.2
        assert q.breaker_state("ds")["trips"] == 2
    finally:
        q.shutdown()


# -- DELETE lifecycle ----------------------------------------------------------

def test_delete_dataset_reclaims_store_and_refuses_while_active(tmp_path):
    faults = ServiceFaultInjector(slow_jobs={"d2": 1.5})
    srv = make_server(tmp_path, faults=faults)
    try:
        data = bsbm_ntriples(60, seed=8)
        job = wait_job(srv.port, "d1", upload(srv.port, "d1", data))
        assert job["state"] == "done"
        ddir = srv.registry.dataset_dir("d1")
        assert os.path.isdir(os.path.join(ddir, "store", "segments"))

        # refused while a job is in flight (409 + Retry-After)
        jid2 = upload(srv.port, "d2", data)
        st, doc, headers = req(srv.port, "DELETE", "/datasets/d2")
        assert st == 409 and "jobs" in doc["error"]
        assert headers.get("Retry-After")
        wait_job(srv.port, "d2", jid2)

        st, doc, _ = req(srv.port, "DELETE", "/datasets/d1")
        assert st == 200 and doc["deleted"] == "d1"
        assert doc["bytes_reclaimed"] > 0
        assert not os.path.exists(ddir)        # segments + records gone
        st, doc, _ = req(srv.port, "GET", "/datasets/d1")
        assert st == 404
        st, doc, _ = req(srv.port, "DELETE", "/datasets/d1")
        assert st == 404                       # idempotent at the API
        # the journal holds the tombstone
        assert any(r["ev"] == "tombstone" and r["dataset"] == "d1"
                   for r in JobJournal.load(srv.journal.path))
        # the name is reusable and starts cold (no stale reuse)
        job3 = wait_job(srv.port, "d1", upload(srv.port, "d1", data))
        assert job3["state"] == "done"
        assert job3["exec_stats"]["segments_reused"] == 0
    finally:
        srv.close()


# -- finished-job retention ----------------------------------------------------

def test_finished_job_retention_cap_evicts_oldest():
    q = JobQueue(workers=2, fn=lambda job: None, max_finished=3)
    try:
        jobs = [q.submit(f"ds{i}") for i in range(8)]
        deadline = time.time() + 20
        while q.depth():
            assert time.time() < deadline
            time.sleep(0.01)
        while q.counts()["done"] > 3:          # eviction is synchronous,
            assert time.time() < deadline      # but jobs finish async
            time.sleep(0.01)
        assert q.counts() == {"queued": 0, "running": 0, "done": 3,
                              "failed": 0}
        retained = q.list()
        assert len(retained) == 3
        assert q.get(jobs[0].id) is None       # oldest evicted
        assert q.get(jobs[-1].id) is not None  # newest retained
    finally:
        q.shutdown()


# -- webhook retries -----------------------------------------------------------

def test_webhook_bounded_retries():
    hits = []

    class Flaky(http.server.BaseHTTPRequestHandler):
        def do_POST(self):
            n = int(self.headers.get("Content-Length") or 0)
            hits.append(json.loads(self.rfile.read(n)))
            code = 500 if len(hits) <= 2 else 200
            self.send_response(code)
            self.send_header("Content-Length", "0")
            self.end_headers()

        def log_message(self, *a):
            pass

    sink = http.server.HTTPServer(("127.0.0.1", 0), Flaky)
    threading.Thread(target=sink.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{sink.server_address[1]}/hook"
    try:
        # two 500s then a 200: succeeds within 3 attempts
        assert post_webhook(url, {"x": 1}, retries=3, backoff=0.01)
        assert len(hits) == 3
        # injected hard failures: bounded, returns False, never raises
        fault = ServiceFaultInjector(fail_webhooks=-1)
        assert not post_webhook(url, {"x": 2}, retries=2, backoff=0.01,
                                fault=fault)
        assert len(hits) == 3                  # injector blocked the POSTs
    finally:
        sink.shutdown()
        sink.server_close()


def test_webhook_final_failure_counted_in_metrics(tmp_path):
    faults = ServiceFaultInjector(fail_webhooks=-1)
    srv = make_server(tmp_path, faults=faults, webhook_retries=2,
                      webhook_backoff=0.01)
    try:
        st, doc, _ = req(srv.port, "PUT", "/datasets/wh",
                         body=json.dumps({
                             "alerts": ["L1 >= 0"],   # always fires
                             "webhook": "http://127.0.0.1:9/hook",
                         }).encode())
        assert st == 201, doc
        job = wait_job(srv.port, "wh",
                       upload(srv.port, "wh", bsbm_ntriples(20, seed=9)))
        assert job["state"] == "done" and job["alerts_fired"] == 1
        st, prom, _ = req(srv.port, "GET", "/metrics")
        assert 'repro_webhook_failures_total{dataset="wh"} 1' \
            in prom.decode()
        # the alert record itself is durable regardless of the webhook
        st, doc, _ = req(srv.port, "GET", "/datasets/wh/alerts")
        assert len(doc["alerts"]) == 1
    finally:
        srv.close()


# -- kill -9 durability (the tentpole guarantee) -------------------------------

_RUNNER = textwrap.dedent("""\
    import sys, time
    root, portfile, mode = sys.argv[1], sys.argv[2], sys.argv[3]
    from repro.serve import QAServer, ServerConfig, ServiceFaultInjector
    faults = None
    if mode == "slow":
        faults = ServiceFaultInjector(
            slow_jobs={"ds1": 5.0, "ds2": 5.0, "ds3": 5.0})
    srv = QAServer(ServerConfig(
        store_root=root, metrics="paper",
        base=("http://bsbm.example.org/",), workers=1,
        segment_bytes=4096, watch=False, retry_base=0.05),
        port=0, faults=faults).start()
    with open(portfile + ".tmp", "w") as f:
        f.write(str(srv.port))
    import os
    os.replace(portfile + ".tmp", portfile)
    while True:
        time.sleep(1)
""")


def _spawn_daemon(runner, root, portfile, mode):
    if os.path.exists(portfile):
        os.remove(portfile)
    proc = subprocess.Popen(
        [sys.executable, os.fspath(runner), os.fspath(root),
         os.fspath(portfile), mode],
        env={**os.environ, "PYTHONPATH": SRC},
        stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    deadline = time.time() + 180
    while not os.path.exists(portfile):
        if proc.poll() is not None:
            raise AssertionError(
                f"daemon died at startup: "
                f"{proc.communicate()[1].decode()[-3000:]}")
        assert time.time() < deadline, "daemon never came up"
        time.sleep(0.05)
    with open(portfile) as f:
        return proc, int(f.read())


def test_kill9_mid_queue_replays_all_accepted_jobs_bit_identical(tmp_path):
    """SIGKILL a daemon with one job running and two queued: the journal
    must carry all three, and the restarted daemon must complete them
    under their original ids with values AND registers bit-identical to
    an uninterrupted run."""
    runner = tmp_path / "runner.py"
    runner.write_text(_RUNNER)
    root = tmp_path / "root"
    portfile = tmp_path / "port"
    datasets = {f"ds{i}": bsbm_ntriples(40, seed=10 + i)
                for i in (1, 2, 3)}

    proc, port = _spawn_daemon(runner, root, portfile, "slow")
    try:
        job_ids = {name: upload(port, name, data)
                   for name, data in datasets.items()}
        # wait until the first job is genuinely mid-run, then kill -9
        deadline = time.time() + 60
        while True:
            st, job, _ = req(port, "GET",
                             f"/datasets/ds1/jobs/{job_ids['ds1']}")
            if job["state"] == "running":
                break
            assert time.time() < deadline, job
            time.sleep(0.01)
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()

    # the fsync'd journal names every accepted job as unfinished
    unfinished, max_id = JobJournal.replay(
        os.path.join(os.fspath(root), "jobs.jsonl"))
    assert {r["id"] for r in unfinished} == set(job_ids.values())
    assert max_id == max(job_ids.values())

    proc2, port2 = _spawn_daemon(runner, root, portfile, "clean")
    try:
        for name, data in datasets.items():
            job = wait_job(port2, name, job_ids[name])   # original id
            assert job["state"] == "done", (name, job["error"])
            cold = qa.assess(data, metrics="paper", base=BASE)
            assert job["values"] == {k: float(v) for k, v in
                                     sorted(cold.values.items())}
            assert job["n_triples"] == cold.n_triples
            # registers: a warm run over the replayed job's store is pure
            # reuse and bit-identical to the uninterrupted cold run
            warm = qa.assess(data, metrics="paper", base=BASE,
                             store=os.path.join(os.fspath(root), name,
                                                "store"),
                             segment_bytes=4096)
            assert warm.exec_stats.segments_rescanned == 0
            assert warm.values == cold.values
            for k in cold.registers:
                assert np.array_equal(warm.registers[k],
                                      cold.registers[k])
        st, prom, _ = req(port2, "GET", "/metrics")
        text = prom.decode()
        replayed = sum(
            int(line.rsplit(" ", 1)[1])
            for line in text.splitlines()
            if line.startswith("repro_jobs_replayed_total{"))
        assert replayed == 3
    finally:
        os.kill(proc2.pid, signal.SIGKILL)
        proc2.wait(timeout=30)


# -- graceful shutdown ---------------------------------------------------------

def test_sigterm_drains_and_exits_zero(tmp_path):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.qa_serve", "--port", "0",
         "--store-root", os.fspath(tmp_path / "root"), "--no-watch"],
        env={**os.environ, "PYTHONPATH": SRC},
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    lines = []
    banner = threading.Event()

    def read_stderr():
        for line in proc.stderr:
            lines.append(line)
            if line.startswith("# repro.serve on http://"):
                banner.set()

    t = threading.Thread(target=read_stderr, daemon=True)
    t.start()
    try:
        assert banner.wait(180), f"no startup banner: {''.join(lines)}"
        proc.send_signal(signal.SIGTERM)
        proc.wait(timeout=60)
        t.join(timeout=10)
        err = "".join(lines)
        assert proc.returncode == 0, err
        assert "SIGTERM" in err and "clean shutdown" in err
    finally:
        if proc.poll() is None:
            proc.kill()

"""The ``--watch`` monitoring loop itself (the store beneath it is covered
by test_store.py): change detection via the one-stat signature, the
``max_assessments`` bound, delta printing, and tolerance of a file that
vanishes mid-poll."""
import io
import os
import threading
import time

import pytest

from repro import qa
from repro.launch.assess import file_signature, watch
from repro.rdf import bsbm_ntriples

BASE = ("http://bsbm.example.org/",)
SEG = 8192


def make_pipe(tmp_path):
    return (qa.pipeline().metrics("paper").base(*BASE)
            .incremental(os.fspath(tmp_path / "store"), segment_bytes=SEG))


def wait_for(cond, timeout=30.0, interval=0.02):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return False


def run_watch(pipe, path, out, max_assessments, interval=0.05,
              timeout=60.0):
    """Run watch() on a daemon thread with a deadline so a regression in
    change detection fails the test instead of hanging the suite."""
    result = {}

    def target():
        result["runs"] = watch(pipe, os.fspath(path), interval=interval,
                               max_assessments=max_assessments, out=out)

    t = threading.Thread(target=target, daemon=True)
    t.start()
    t.join(timeout)
    assert not t.is_alive(), (
        f"watch() did not terminate within {timeout}s "
        f"(max_assessments={max_assessments}); output so far:\n"
        + out.getvalue())
    return result["runs"]


# -- the signature helper ------------------------------------------------------

def test_file_signature_one_stat_fields(tmp_path):
    p = tmp_path / "d.nt"
    p.write_text("x\n")
    st = os.stat(p)
    assert file_signature(p) == (st.st_mtime_ns, st.st_size, st.st_ino)
    with pytest.raises(OSError):
        file_signature(tmp_path / "missing.nt")


def test_file_signature_catches_same_size_atomic_replace(tmp_path):
    """A same-length atomic replace with a *forced identical mtime* (the
    worst case inside mtime granularity) still changes the signature,
    because tmp+``os.replace`` swaps the inode.  The old (getmtime,
    getsize) pair is blind to exactly this edit."""
    p = tmp_path / "d.nt"
    a = '<http://e/s1> <http://e/p> "x" .\n'
    b = '<http://e/s2> <http://e/p> "x" .\n'
    assert len(a) == len(b)
    p.write_text(a)
    sig_a = file_signature(p)
    st = os.stat(p)
    tmp = tmp_path / "d.nt.tmp"
    tmp.write_text(b)
    os.utime(tmp, ns=(st.st_atime_ns, st.st_mtime_ns))
    os.replace(tmp, p)
    sig_b = file_signature(p)
    assert sig_b != sig_a
    # the pre-fix signature misses it:
    old_style = (os.path.getmtime(p), os.path.getsize(p))
    assert old_style == (st.st_mtime, st.st_size)


# -- the loop ------------------------------------------------------------------

def test_watch_reassesses_on_edit_and_prints_deltas(tmp_path):
    nt = tmp_path / "d.nt"
    nt.write_text(bsbm_ntriples(40, seed=0))
    hist = tmp_path / "store" / "history.jsonl"
    out = io.StringIO()

    def editor():
        # wait for the first assessment to land, then append new triples
        assert wait_for(lambda: hist.exists()
                        and len(hist.read_text().splitlines()) >= 1)
        with open(nt, "a") as f:
            f.write(bsbm_ntriples(8, seed=9))

    t = threading.Thread(target=editor, daemon=True)
    t.start()
    runs = run_watch(make_pipe(tmp_path), nt, out, max_assessments=2)
    t.join(10)
    assert runs == 2
    text = out.getvalue()
    assert text.count("change detected") == 2
    assert "# deltas:" in text           # printed from the second run on
    # both snapshots went through the store
    assert len(hist.read_text().splitlines()) == 2


def test_watch_max_assessments_bounds_the_loop(tmp_path):
    nt = tmp_path / "d.nt"
    nt.write_text(bsbm_ntriples(20, seed=1))
    out = io.StringIO()
    runs = run_watch(make_pipe(tmp_path), nt, out, max_assessments=1)
    assert runs == 1                     # returns after one, no hang
    assert out.getvalue().count("change detected") == 1


def test_watch_tolerates_file_missing_mid_poll(tmp_path):
    nt = tmp_path / "appears-later.nt"
    out = io.StringIO()

    def creator():
        time.sleep(0.3)                  # a few polls see OSError first
        nt.write_text(bsbm_ntriples(20, seed=2))

    t = threading.Thread(target=creator, daemon=True)
    t.start()
    runs = run_watch(make_pipe(tmp_path), nt, out, max_assessments=1)
    t.join(10)
    assert runs == 1
    assert "change detected" in out.getvalue()


def test_watch_detects_same_size_replace_end_to_end(tmp_path):
    """The loop-level version of the signature test: a same-size replace
    with a pinned mtime triggers a re-assessment."""
    nt = tmp_path / "d.nt"
    a = '<http://e/s1> <http://e/p> "x" .\n'
    b = '<http://e/s2> <http://e/p> "x" .\n'
    nt.write_text(a)
    hist = tmp_path / "store" / "history.jsonl"
    out = io.StringIO()

    def replacer():
        assert wait_for(lambda: hist.exists()
                        and len(hist.read_text().splitlines()) >= 1)
        st = os.stat(nt)
        tmp = tmp_path / "repl.tmp"
        tmp.write_text(b)
        os.utime(tmp, ns=(st.st_atime_ns, st.st_mtime_ns))
        os.replace(tmp, nt)

    t = threading.Thread(target=replacer, daemon=True)
    t.start()
    runs = run_watch(make_pipe(tmp_path), nt, out, max_assessments=2)
    t.join(10)
    assert runs == 2
